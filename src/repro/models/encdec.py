"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment carve-out, the mel-spectrogram + conv feature extractor is a
STUB: ``input_specs`` feeds precomputed frame embeddings [B, enc_seq, d_model].
Everything downstream — sinusoidal positions, bidirectional encoder, causal
decoder with self+cross attention, KV caches — is implemented.

Speculative sampling applies to the decoder; the encoder runs once per request
and its output (and the per-layer cross-attention K/V) is cached.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.cache import kv_cache
from repro.models import dense
from repro.models import layers as L
from repro.models.attention import attention


def sinusoid(positions, d_model):
    half = d_model // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------- init
def init_enc_layer(key, cfg):
    ka, km = jax.random.split(key)
    return {"attn": dense.init_attn(ka, cfg),
            "mlp_norm": L.init_rmsnorm(cfg.d_model, cfg.weight_dtype),
            "mlp": L.init_gelu_mlp(km, cfg.d_model, cfg.d_ff, cfg.weight_dtype)}


def init_dec_layer(key, cfg):
    ka, kx, km = jax.random.split(key, 3)
    return {"self": dense.init_attn(ka, cfg),
            "cross": dense.init_attn(kx, cfg),
            "mlp_norm": L.init_rmsnorm(cfg.d_model, cfg.weight_dtype),
            "mlp": L.init_gelu_mlp(km, cfg.d_model, cfg.d_ff, cfg.weight_dtype)}


def init(cfg, rng):
    ke, kenc, kdec, kn = jax.random.split(rng, 4)
    return {
        "embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, cfg.weight_dtype,
                                  scale=cfg.embed_init_scale),
        "enc_layers": dense._stack_layers(kenc, cfg, init_enc_layer, cfg.num_encoder_layers),
        "enc_norm": L.init_rmsnorm(cfg.d_model, cfg.weight_dtype),
        "dec_layers": dense._stack_layers(kdec, cfg, init_dec_layer, cfg.num_layers),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.weight_dtype),
    }


# ------------------------------------------------------------------- encoder
def encode(cfg, params, frames):
    """frames: [B, T_enc, D] precomputed frame embeddings (stub frontend)."""
    B, T, _ = frames.shape
    pos = jnp.arange(T, dtype=jnp.int32)
    x = frames.astype(cfg.act_dtype) + sinusoid(pos, cfg.d_model).astype(cfg.act_dtype)

    def enc_block(h, lp):
        pa = lp["attn"]
        hn = L.rmsnorm(pa["norm"], h, cfg.norm_eps)
        hd = cfg.head_dim
        q = L.linear(pa["q"], hn).reshape(B, T, cfg.num_heads, hd)
        k = L.linear(pa["k"], hn).reshape(B, T, cfg.num_kv_heads, hd)
        v = L.linear(pa["v"], hn).reshape(B, T, cfg.num_kv_heads, hd)
        o = attention(q, k, v, pos, pos, causal=False)
        h = h + L.linear(pa["o"], o.reshape(B, T, cfg.num_heads * hd))
        h = h + L.gelu_mlp(lp["mlp"], L.rmsnorm(lp["mlp_norm"], h, cfg.norm_eps))
        return h, None

    if cfg.remat:
        enc_block = L.remat_wrap(enc_block, cfg)
    x, _ = jax.lax.scan(enc_block, x, params["enc_layers"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def cross_kv(cfg, params, enc_out):
    """Precompute per-decoder-layer cross-attention K/V from encoder output."""
    B, T, _ = enc_out.shape
    hd = cfg.head_dim

    def one(lp):
        k = L.linear(lp["cross"]["k"], enc_out).reshape(B, T, cfg.num_kv_heads, hd)
        v = L.linear(lp["cross"]["v"], enc_out).reshape(B, T, cfg.num_kv_heads, hd)
        return {"k": k, "v": v}

    return jax.vmap(one)(params["dec_layers"])   # stacked [L_dec, B, T, Kv, hd]


# ------------------------------------------------------------------- decoder
def forward(cfg, params, tokens, cache=None, *, cross=None, logits_slice=None):
    """Decoder pass. cross: stacked cross-KV from ``cross_kv`` (required).
    cache: self-attention KV cache (or None for a full causal pass)."""
    B, Q = tokens.shape
    index = cache["index"] if cache is not None else jnp.zeros((), jnp.int32)
    q_pos = index + jnp.arange(Q, dtype=jnp.int32)
    x = L.embed(params["embed"], tokens).astype(cfg.act_dtype)
    x = x + sinusoid(q_pos, cfg.d_model).astype(cfg.act_dtype)
    T_enc = cross["k"].shape[2]
    enc_pos = jnp.arange(T_enc, dtype=jnp.int32)
    hd = cfg.head_dim

    def dec_block(h, lp, lc, lcross):
        # causal self-attention (cached)
        o, new_kv = dense.attn_block(cfg, lp["self"], h, q_pos, lc, index, None,
                                     use_rope=False)
        h = h + o
        # cross-attention (static KV)
        pc = lp["cross"]
        hn = L.rmsnorm(pc["norm"], h, cfg.norm_eps)
        q = L.linear(pc["q"], hn).reshape(B, Q, cfg.num_heads, hd)
        o = attention(q, lcross["k"], lcross["v"], q_pos, enc_pos, causal=False)
        h = h + L.linear(pc["o"], o.reshape(B, Q, cfg.num_heads * hd))
        h = h + L.gelu_mlp(lp["mlp"], L.rmsnorm(lp["mlp_norm"], h, cfg.norm_eps))
        return h, new_kv

    if cache is None:
        def step_nc(h, xs):
            lp, lcross = xs
            h, _ = dec_block(h, lp, None, lcross)
            return h, None
        if cfg.remat:
            step_nc = L.remat_wrap(step_nc, cfg)
        x, _ = jax.lax.scan(step_nc, x, (params["dec_layers"], cross))
        new_cache = None
    else:
        layer_kv = {"k": cache["k"], "v": cache["v"]}
        def step(h, xs):
            lp, lc, lcross = xs
            h, new_kv = dec_block(h, lp, lc, lcross)
            return h, new_kv
        x, new_kv = jax.lax.scan(step, x, (params["dec_layers"], layer_kv, cross))
        new_cache = {"k": new_kv["k"], "v": new_kv["v"], "index": index + Q}

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if logits_slice == "last":
        x = x[:, -1:]
    logits = L.unembed(params["embed"], x)   # whisper ties embeddings
    return logits, new_cache
