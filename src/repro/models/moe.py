"""Mixture-of-Experts transformer (Mixtral / Llama-4 style).

Expert compute uses the GShard-style capacity-based dispatch/combine einsum
formulation: tokens are grouped, each expert accepts at most C tokens per group,
and dispatch/combine are expressed as dense einsums that GSPMD turns into
all-to-alls when the expert axis is sharded. This is the standard TPU "dropped"
MoE (cf. GShard, Switch, MaxText): it compiles for every mesh and its FLOP
overhead (the dispatch einsums) is ~5% of expert FLOPs at our shapes.

Routing: softmax over experts -> top-k -> renormalize (Mixtral convention).
Aux losses (load-balance + router z-loss) are accumulated through the layer scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import dense
from repro.models import layers as L


# ---------------------------------------------------------------------- init
def init_moe_mlp(key, cfg):
    kr, ke, ks = jax.random.split(key, 3)
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = cfg.weight_dtype
    keys = jax.random.split(ke, E)
    experts = jax.vmap(lambda k: L.init_swiglu(k, d, f, dt))(keys)  # stacked [E, ...]
    p = {"router": L.init_linear(kr, d, E, dt, scale=d ** -0.5), "experts": experts}
    if cfg.num_shared_experts:
        p["shared"] = L.init_swiglu(ks, d, cfg.num_shared_experts * f, dt)
    return p


def init_layer(key, cfg):
    ka, km = jax.random.split(key)
    return {
        "attn": dense.init_attn(ka, cfg),
        "mlp_norm": L.init_rmsnorm(cfg.d_model, cfg.weight_dtype),
        "moe": init_moe_mlp(km, cfg),
    }


def init_block(key, cfg):
    """One scan block: (moe_every - 1) dense-MLP layers followed by one MoE
    layer (llama4-style interleaving; moe_every=1 -> every layer MoE)."""
    n_dense = max(cfg.moe_every - 1, 0)
    keys = jax.random.split(key, n_dense + 1)
    block = {f"dense{i}": dense.init_layer(keys[i], cfg) for i in range(n_dense)}
    block["moe"] = init_layer(keys[-1], cfg)
    return block


def init(cfg, rng):
    ke, kl, kh = jax.random.split(rng, 3)
    n_blocks = cfg.num_layers // max(cfg.moe_every, 1)
    params = {
        "embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, cfg.weight_dtype,
                                  scale=cfg.embed_init_scale),
        "layers": dense._stack_layers(kl, cfg, init_block, n_blocks),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.weight_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_linear(kh, cfg.d_model, cfg.vocab_size, cfg.weight_dtype)
    return params


# ------------------------------------------------------------------- routing
def group_shape(T: int) -> int:
    """Tokens per routing group. Groups bound the capacity buffer size."""
    for g in (2048, 1024, 512, 256, 128):
        if T % g == 0:
            return g
    return T


def moe_mlp(cfg, p, x):
    """x: [B, S, D] -> (y, aux_losses). Routes over flattened (B*S) tokens."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    g = group_shape(T)
    n_groups = T // g
    # dropless for small token counts (decode / speculative verify): capacity
    # dropping is a *training* throughput trade; serving must be exact so the
    # cached and uncached paths agree and greedy spec-decode stays lossless.
    if g * K <= 512:
        cap = g
    else:
        cap = max(1, int(g * K / E * 1.25))                   # capacity factor 1.25

    xt = x.reshape(n_groups, g, D)
    logits = L.linear(p["router"], xt).astype(jnp.float32)     # [G, g, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                     # [G, g, K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's capacity buffer.
    # priority: token order within the group, then choice order.
    assign = jax.nn.one_hot(top_e, E, dtype=jnp.int32)         # [G, g, K, E]
    flat = assign.reshape(n_groups, g * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                      # tokens ahead of me
    pos = pos.reshape(n_groups, g, K, E)
    within_cap = (pos < cap) & (assign > 0)
    # a token routes to each expert at most once, so the K axis can be folded
    # BEFORE the capacity one-hot — the [G,g,K,E,C] intermediate never exists
    # (it dominated temp memory in the first dry-run; see docs/DESIGN.md §Perf)
    pos_e = jnp.sum(pos * within_cap, axis=2)                  # [G, g, E]
    sel_e = jnp.any(within_cap, axis=2)                        # [G, g, E]
    gate_e = jnp.sum(top_p[..., None] * within_cap, axis=2)    # [G, g, E]
    disp = (jax.nn.one_hot(pos_e, cap, dtype=x.dtype)
            * sel_e[..., None].astype(x.dtype))                # [G, g, E, C]
    comb = disp * gate_e[..., None].astype(x.dtype)

    xe = jnp.einsum("gsec,gsd->gecd", disp, xt)                # [G, E, C, D]
    w = p["experts"]

    def ew(wd):  # expert weight, handling int8 serving quantization
        if "w_q" in wd:
            return (wd["w_q"].astype(x.dtype)
                    * wd["scale"][:, None, :].astype(x.dtype))
        return wd["w"].astype(x.dtype)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, ew(w["gate"])))
    h = h * jnp.einsum("gecd,edf->gecf", xe, ew(w["up"]))
    ye = jnp.einsum("gecf,efd->gecd", h, ew(w["down"]))
    y = jnp.einsum("gsec,gecd->gsd", comb, ye).reshape(B, S, D)

    if cfg.num_shared_experts:
        y = y + L.swiglu(p["shared"], x)

    # aux: load-balance (Switch) + router z-loss
    density = assign.astype(jnp.float32).sum(2).mean(1)        # [G, E] token fraction
    router_mean = probs.mean(1)                                # [G, E]
    lb = (density * router_mean).sum(-1).mean() * E
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return y, {"load_balance": lb, "router_z": z}


def moe_layer(cfg, p, x, q_pos, layer_cache, index, block_table=None,
              max_live=None):
    o, new_cache = dense.attn_block(cfg, p["attn"], x, q_pos, layer_cache, index,
                                    cfg.sliding_window, block_table=block_table,
                                    max_live=max_live)
    x = x + o
    y, aux = moe_mlp(cfg, p["moe"], L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps))
    return x + y, new_cache, aux


def moe_block(cfg, bp, x, q_pos, block_cache, index, block_table=None,
              max_live=None):
    """(moe_every-1) dense layers + 1 MoE layer; caches keyed like params."""
    n_dense = max(cfg.moe_every - 1, 0)
    new_bc = {}
    for i in range(n_dense):
        key = f"dense{i}"
        lc = block_cache[key] if block_cache is not None else None
        x, nc = dense.dense_layer(cfg, bp[key], x, q_pos, lc, index, block_table,
                                  max_live)
        new_bc[key] = nc
    lc = block_cache["moe"] if block_cache is not None else None
    x, nc, aux = moe_layer(cfg, bp["moe"], x, q_pos, lc, index, block_table,
                           max_live)
    new_bc["moe"] = nc
    return x, (new_bc if block_cache is not None else None), aux


def forward(cfg, params, tokens, cache=None, *, input_embeds=None, logits_slice=None,
            max_live=None):
    x = input_embeds if input_embeds is not None else L.embed(params["embed"], tokens)
    x = x.astype(cfg.act_dtype)
    B, Q = x.shape[0], x.shape[1]
    index = cache["index"] if cache is not None else jnp.zeros((), jnp.int32)
    block_table = cache.get("block_table") if cache is not None else None
    # index: scalar (shared) or [B] (per-row batched speculation)
    q_pos = (jnp.asarray(index)[..., None] + jnp.arange(Q, dtype=jnp.int32)
             if jnp.asarray(index).ndim else index + jnp.arange(Q, dtype=jnp.int32))

    def step(carry, xs):
        h, lb, rz = carry
        lp, lc = xs
        h, new_lc, aux = moe_block(cfg, lp, h, q_pos, lc, index, block_table,
                                   max_live)
        return (h, lb + aux["load_balance"], rz + aux["router_z"]), new_lc

    zero = jnp.zeros((), jnp.float32)
    if cfg.remat:
        step = L.remat_wrap(step, cfg)
    if cache is None:
        n = cfg.num_layers
        def step_nc(carry, lp):
            h, lb, rz = carry
            h, _, aux = moe_block(cfg, lp, h, q_pos, None, index)
            return (h, lb + aux["load_balance"], rz + aux["router_z"]), None
        if cfg.remat:
            step_nc = L.remat_wrap(step_nc, cfg)
        (x, lb, rz), _ = jax.lax.scan(step_nc, (x, zero, zero), params["layers"])
        new_kv = None
    else:
        layer_kv = cache["blocks"]
        (x, lb, rz), new_kv = jax.lax.scan(step, (x, zero, zero),
                                           (params["layers"], layer_kv))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if logits_slice == "last":
        x = x[:, -1:]
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = L.linear(params["lm_head"], x.astype(jnp.float32))
    n_blocks = cfg.num_layers // max(cfg.moe_every, 1)
    aux = {"load_balance": lb / n_blocks, "router_z": rz / n_blocks}
    if cache is None:
        return logits, None, aux
    new_cache = {"blocks": new_kv, "index": index + Q}
    if block_table is not None:
        new_cache["block_table"] = block_table
    return logits, new_cache, aux
