"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention
(arXiv:2402.19427), pattern (rec, rec, attn) cycled — "1:2" in the assignment.

Structure: the layer stack is split into full (rec, rec, attn) *blocks* scanned
with lax.scan, plus a tail of leftover rec layers (26 = 8 blocks x 3 + 2 tail)
scanned separately, so compile cost stays depth-independent.

RG-LRU recurrence  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t ⊙ u_t)  with
a_t = exp(-c * softplus(Λ) * r_t) is evaluated with an associative scan over
(a, b) pairs for sequence inputs and as a single fused step for decode. Rollback
uses a per-token state trail, as for the SSM family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.cache import kv_cache
from repro.models import dense
from repro.models import layers as L

RGLRU_C = 8.0


# ---------------------------------------------------------------------- init
def init_rec_layer(key, cfg):
    d = cfg.d_model
    w = cfg.lru_width or d
    kx, kg, kr, ki, ko, kc = jax.random.split(key, 6)
    dt = cfg.weight_dtype
    return {
        "norm": L.init_rmsnorm(d, dt),
        "in_x": L.init_linear(kx, d, w, dt),
        "in_gate": L.init_linear(kg, d, w, dt),
        "conv_w": (jax.random.normal(kc, (4, w), jnp.float32) * 0.5).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "gate_r": L.init_linear(kr, w, w, dt),
        "gate_i": L.init_linear(ki, w, w, dt),
        # Λ init so that a^c is roughly uniform in (0.9, 0.999) — griffin practice
        "lam": jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, w)) / RGLRU_C)),
        "out": L.init_linear(ko, w, d, dt),
    }


def init_unit(key, cfg, kind):
    km, kb = jax.random.split(key)
    mix = (init_rec_layer(kb, cfg) if kind == "rec"
           else dense.init_attn(kb, cfg))
    return {
        "mix": mix,
        "mlp_norm": L.init_rmsnorm(cfg.d_model, cfg.weight_dtype),
        "mlp": L.init_swiglu(km, cfg.d_model, cfg.d_ff, cfg.weight_dtype),
    }


def layout(cfg):
    """(n_blocks, tail_kinds): full pattern blocks + leftover layers."""
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    n_blocks = cfg.num_layers // len(pat)
    tail = tuple(pat[i % len(pat)] for i in range(cfg.num_layers - n_blocks * len(pat)))
    return n_blocks, pat, tail


def init(cfg, rng):
    n_blocks, pat, tail = layout(cfg)
    ke, kb, kt = jax.random.split(rng, 3)

    def init_block(key):
        keys = jax.random.split(key, len(pat))
        return {f"u{i}_{kind}": init_unit(keys[i], cfg, kind)
                for i, kind in enumerate(pat)}

    params = {
        "embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, cfg.weight_dtype,
                                  scale=cfg.embed_init_scale),
        "blocks": jax.vmap(init_block)(jax.random.split(kb, n_blocks)),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.weight_dtype),
    }
    if tail:
        tkeys = jax.random.split(kt, len(tail))
        params["tail"] = [init_unit(tkeys[i], cfg, kind) for i, kind in enumerate(tail)]
    return params


# -------------------------------------------------------------------- RG-LRU
def rglru(p, u, state, want_trail):
    """u: [B,Q,W] conv output; state: [B,W] or None (zeros). Returns (y, final, trail)."""
    B, Q, W = u.shape
    r = jax.nn.sigmoid(L.linear(p["gate_r"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(L.linear(p["gate_i"], u).astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r            # [B,Q,W]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u.astype(jnp.float32))
    if state is None:
        state = jnp.zeros((B, W), jnp.float32)

    if Q == 1:
        h = a[:, 0] * state + gated[:, 0]
        y = h[:, None]
        return y, h, (y if want_trail else None)

    # associative scan over (a, b): compose (a2a1, a2 b1 + b2); fold init state in
    b0 = gated.at[:, 0].add(a[:, 0] * state)
    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, hs = jax.lax.associative_scan(comb, (a, b0), axis=1)
    final = hs[:, -1]
    return hs, final, (hs if want_trail else None)


def rec_unit(cfg, p, x, layer_cache, want_trail):
    """Recurrent temporal-mixing unit. layer_cache: {"state":[B,W], "conv":[B,3,W]}."""
    pm = p["mix"]
    h = L.rmsnorm(pm["norm"], x, cfg.norm_eps)
    gate = jax.nn.gelu(L.linear(pm["in_gate"], h))
    u_raw = L.linear(pm["in_x"], h)
    conv_cache = layer_cache["conv"] if layer_cache is not None else None
    from repro.models.ssm import _causal_conv
    u, new_conv = _causal_conv(u_raw, pm["conv_w"], pm["conv_b"], conv_cache)
    state = layer_cache["state"].astype(jnp.float32) if layer_cache is not None else None
    y, final, trail = rglru(pm, u, state, want_trail)
    y = (y.astype(x.dtype) * gate)
    out = L.linear(pm["out"], y)
    x = x + out
    x = x + L.swiglu(p["mlp"], L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps))
    new_cache = None
    if layer_cache is not None:
        new_cache = {"state": final.astype(layer_cache["state"].dtype),
                     "conv": new_conv.astype(layer_cache["conv"].dtype)}
        if want_trail:
            Q = x.shape[1]
            K = pm["conv_w"].shape[0]
            xfull = jnp.concatenate([conv_cache.astype(u_raw.dtype), u_raw], axis=1)
            conv_trail = jnp.stack([xfull[:, j + 1:j + K] for j in range(Q)], axis=1)
            new_cache["state_trail"] = trail.astype(layer_cache["state"].dtype)
            new_cache["conv_trail"] = conv_trail.astype(layer_cache["conv"].dtype)
    return x, new_cache


def attn_unit(cfg, p, x, q_pos, layer_cache, index):
    o, new_kv = dense.attn_block(cfg, p["mix"], x, q_pos, layer_cache, index,
                                 cfg.local_window)
    x = x + o
    x = x + L.swiglu(p["mlp"], L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps))
    return x, new_kv


# ------------------------------------------------------------------- forward
def forward(cfg, params, tokens, cache=None, *, input_embeds=None,
            logits_slice=None, want_trail=False):
    n_blocks, pat, tail = layout(cfg)
    x = input_embeds if input_embeds is not None else L.embed(params["embed"], tokens)
    x = x.astype(cfg.act_dtype)
    B, Q = x.shape[0], x.shape[1]
    index = cache["index"] if cache is not None else jnp.zeros((), jnp.int32)
    q_pos = index + jnp.arange(Q, dtype=jnp.int32)

    def run_unit(i, kind, up, h, uc):
        if kind == "rec":
            return rec_unit(cfg, up, h, uc, want_trail)
        return attn_unit(cfg, up, h, q_pos, uc, index)

    def block_fn(h, bp, bc):
        new_bc = {}
        for i, kind in enumerate(pat):
            key = f"u{i}_{kind}"
            uc = bc[key] if bc is not None else None
            h, nuc = run_unit(i, kind, bp[key], h, uc)
            new_bc[key] = nuc
        return h, (new_bc if bc is not None else None)

    if cache is None:
        def step_nc(h, bp):
            h, _ = block_fn(h, bp, None)
            return h, None
        if cfg.remat:
            step_nc = L.remat_wrap(step_nc, cfg)
        x, _ = jax.lax.scan(step_nc, x, params["blocks"])
        for i, kind in enumerate(tail):
            x, _ = run_unit(i, kind, params["tail"][i], x, None)
        new_cache = None
    else:
        block_c = cache["blocks"]
        def step(h, xs):
            bp, bc = xs
            return block_fn(h, bp, bc)
        x, new_block_c = jax.lax.scan(step, x, (params["blocks"], block_c))
        new_tail_c = []
        for i, kind in enumerate(tail):
            x, nuc = run_unit(i, kind, params["tail"][i], x, cache["tail"][i])
            new_tail_c.append(nuc)
        new_cache = {"blocks": new_block_c, "tail": new_tail_c, "index": index + Q}

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if logits_slice == "last":
        x = x[:, -1:]
    logits = L.unembed(params["embed"], x)  # recurrentgemma ties embeddings
    return logits, new_cache


# --------------------------------------------------------------------- cache
def init_cache(cfg, batch, max_len, spec_slack=0, dtype=jnp.bfloat16):
    n_blocks, pat, tail = layout(cfg)
    w = cfg.lru_width or cfg.d_model
    W = kv_cache.buffer_len(max_len, cfg.local_window + spec_slack)

    def unit_cache(kind, lead):
        if kind == "rec":
            return {"state": jnp.zeros(lead + (batch, w), dtype),
                    "conv": jnp.zeros(lead + (batch, 3, w), dtype)}
        return {"k": jnp.zeros(lead + (batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros(lead + (batch, W, cfg.num_kv_heads, cfg.head_dim), dtype)}

    blocks = {f"u{i}_{kind}": unit_cache(kind, (n_blocks,)) for i, kind in enumerate(pat)}
    return {"blocks": blocks,
            "tail": [unit_cache(kind, ()) for kind in tail],
            "index": jnp.zeros((), jnp.int32)}


def rollback(cache, accepted_index, q_len):
    """Rollback: attn units via index; rec units via their state trail."""
    old_index = cache["index"] - q_len
    j = jnp.clip(accepted_index - old_index - 1, 0, q_len - 1)

    def roll_unit(uc):
        if "state_trail" in uc:
            lead_axis = uc["state_trail"].ndim - 2 - 1  # [..., B, Q, W] -> Q axis
            return {"state": jnp.take(uc["state_trail"], j, axis=-2),
                    "conv": jnp.take(uc["conv_trail"], j, axis=-3)}
        return {"k": uc["k"], "v": uc["v"]}

    new_blocks = {k: roll_unit(v) for k, v in cache["blocks"].items()}
    new_tail = [roll_unit(u) for u in cache["tail"]]
    return {"blocks": new_blocks, "tail": new_tail,
            "index": jnp.asarray(accepted_index, jnp.int32)}
