"""Shared neural-net primitives (pure JAX, functional).

Parameters are plain nested dicts of jnp arrays; every init_* function has a
matching spec_* function in repro.models.specs producing a PartitionSpec tree
with identical structure (enforced by tests/test_specs.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------- init
def _dense_init(key, shape, dtype, scale=None):
    """Truncated-normal fan-in init (matches common LLM practice)."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def init_linear(key, d_in, d_out, dtype, scale=None):
    return {"w": _dense_init(key, (d_in, d_out), dtype, scale)}


def linear(p, x):
    from repro.quant.int8 import maybe_quant_act  # cheap no-op unless enabled
    x = maybe_quant_act(x)
    if "w_q" in p:
        # int8 serving weights: dequant fuses into the matmul read, so HBM
        # traffic is 1 byte/weight (the paper's w8 deployment path; the Pallas
        # int8 kernel is the TPU drop-in that also feeds the MXU in int8)
        w = p["w_q"].astype(x.dtype) * p["scale"].astype(x.dtype)
        return x @ w
    return x @ p["w"].astype(x.dtype)


def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S] int32."""
    freqs = rope_freqs(x.shape[-1], theta)                       # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs    # [..., S, D/2]
    cos, sin = jnp.cos(angles)[..., None, :], jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- MLP
def init_swiglu(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d_model, d_ff, dtype),
        "up": init_linear(k2, d_model, d_ff, dtype),
        "down": init_linear(k3, d_ff, d_model, dtype),
    }


def swiglu(p, x):
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))


def init_gelu_mlp(key, d_model, d_ff, dtype):
    k1, k2 = jax.random.split(key)
    return {"up": init_linear(k1, d_model, d_ff, dtype),
            "down": init_linear(k2, d_ff, d_model, dtype)}


def gelu_mlp(p, x):
    return linear(p["down"], jax.nn.gelu(linear(p["up"], x)))


# --------------------------------------------------------------------------- embeddings
def init_embedding(key, vocab, d_model, dtype, scale=None):
    """``scale=None`` keeps the historical std-1.0 table (golden-pinned);
    models thread ``cfg.embed_init_scale`` through here."""
    return {"table": _dense_init(key, (vocab, d_model), dtype,
                                 scale=1.0 if scale is None else scale)}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x):
    """Tied unembedding: project hidden states to vocab logits (fp32)."""
    return (x.astype(jnp.float32) @ p["table"].astype(jnp.float32).T)


def remat_wrap(fn, cfg):
    """jax.checkpoint with the configured policy ("full" recomputes everything;
    "dots" saves matmul outputs — less recompute, more live memory)."""
    import jax
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)
