"""Partition-spec derivation for params, caches, and step inputs/outputs.

Specs are derived *structurally*: we walk the (eval_shape'd) param pytree and
assign a PartitionSpec per leaf from its key-path and rank. This keeps specs in
lockstep with init functions by construction (tests assert the trees match).

The sharding policy is the compiler-level "device affinity" abstraction of the
paper (§III-D): the speculative-sampling engine assigns the drafter and target
each their own policy/submesh, and the DSE in repro.core.partition searches over
these assignments.

Baseline layout (megatron-style):
  * attention q/k/v: output (heads) on ``model``;  o: input on ``model``
  * mlp gate/up: d_ff on ``model``;  down: d_ff (input) on ``model``
  * embeddings & lm_head: vocab on ``model``
  * MoE experts: expert axis on ``model`` when divisible, else d_ff
  * batch on ``data`` (and ``pod``) when divisible, else replicated
  * with ``fsdp=True``, the non-model axis of every weight is additionally
    sharded over ``data`` (ZeRO-3 style; used by the train step)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]


@dataclass(frozen=True)
class ShardingPolicy:
    data: Axis = "data"            # batch axis name(s) — ("pod","data") multi-pod
    model: Axis = "model"          # tensor axis name
    fsdp: bool = False             # additionally shard weights over `data`
    shard_experts: bool = True     # expert-parallel MoE when divisible
    expert_2d: bool = False        # also shard expert d_ff over `data` (huge MoE)
    replicate_batch: bool = False  # 2D-TP serving: batch replicated, weights 2D
    mesh_axis_sizes: dict = field(default_factory=dict)  # name -> size (for divisibility)

    def axis_size(self, ax: Axis) -> int:
        if ax is None:
            return 1
        names = (ax,) if isinstance(ax, str) else ax
        n = 1
        for a in names:
            n *= self.mesh_axis_sizes.get(a, 1)
        return n

    def batch_axis(self, batch: int) -> Axis:
        if self.replicate_batch:
            return None
        return self.data if batch % max(self.axis_size(self.data), 1) == 0 else None


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def _wspec(pol: ShardingPolicy, rank: int, shard_dim: int, leaf, stacked_dims: int):
    """Weight spec: `shard_dim` (relative to the matrix dims) on model axis.
    `stacked_dims` leading axes (layer/expert stacks) are unsharded unless noted."""
    spec = [None] * rank
    mat_start = stacked_dims
    spec[mat_start + shard_dim] = pol.model
    if pol.fsdp:
        other = mat_start + (1 - shard_dim)
        size = pol.axis_size(pol.data)
        if leaf.shape[other] % max(size, 1) == 0 and size > 1:
            spec[other] = pol.data
    return P(*spec)


OUT_SHARDED = ("q", "k", "v", "gate", "up", "fc1", "in_x", "in_gate",
               "in_proj", "gate_r", "gate_i", "lm_head")


def _quant_scale_spec(ps, leaf, pol, m_size):
    """Spec for int8 per-output-channel scales [..., N]: follows the sibling
    weight's output-dim sharding; K-sharded weights have replicated scales."""
    rank = len(leaf.shape)
    parent = ps.rsplit("/", 2)[-2]
    spec = [None] * rank
    if "/experts/" in ps:
        # expert scales [L, E, N] (or [L, E, D] for down): expert dim rank-2
        if pol.shard_experts and leaf.shape[rank - 2] % max(m_size, 1) == 0:
            spec[rank - 2] = pol.model
            if pol.expert_2d and not ps.endswith("down/scale"):
                d_size = pol.axis_size(pol.data)
                if d_size > 1 and leaf.shape[-1] % d_size == 0:
                    spec[-1] = pol.data
            return P(*spec)
        if parent in ("gate", "up") and leaf.shape[-1] % max(m_size, 1) == 0:
            spec[-1] = pol.model
        return P(*spec)
    if parent in OUT_SHARDED and leaf.shape[-1] % max(m_size, 1) == 0:
        spec[-1] = pol.model
    return P(*spec)


def param_specs(cfg, params_shape, pol: ShardingPolicy):
    """params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape(model.init))."""
    m_size = pol.axis_size(pol.model)

    def rule(path, leaf):
        ps = _path_str(path).replace("/w_q", "/w")   # int8 weights share w rules
        rank = len(leaf.shape)
        stacked = rank - 2  # layer-stack (and expert) leading dims for matrices
        if ps.endswith("/scale") and "norm" not in ps.rsplit("/", 2)[-2]:
            return _quant_scale_spec(ps, leaf, pol, m_size)

        def div(dim_idx):
            return leaf.shape[dim_idx] % max(m_size, 1) == 0

        # ---- embeddings / unembedding: vocab on model
        if ps.endswith("embed/table"):
            return P(pol.model, None) if div(0) else P(None, None)
        if "lm_head" in ps:
            return _wspec(pol, rank, 1, leaf, rank - 2) if div(rank - 1) else P(*([None] * rank))
        # ---- MoE experts: [L, E, D, F]
        if "/experts/" in ps or ps.startswith("experts/"):
            # expert weights are [E, D, F] or layer-stacked [L, E, D, F]:
            # the expert axis is always third-from-last.
            E = leaf.shape[rank - 3]
            if pol.shard_experts and E % max(m_size, 1) == 0:
                spec = [None] * rank
                spec[rank - 3] = pol.model          # expert dim
                if pol.expert_2d:
                    d_size = pol.axis_size(pol.data)
                    ff_dim = rank - 1 if not ps.endswith("down/w") else rank - 2
                    if d_size > 1 and leaf.shape[ff_dim] % d_size == 0:
                        spec[ff_dim] = pol.data     # 2D expert sharding
                return P(*spec)
            shard_dim = 0 if ps.endswith("down/w") else 1
            return _wspec(pol, rank, shard_dim, leaf, rank - 2)
        if "router" in ps:
            return P(*([None] * rank))
        # ---- attention
        if any(ps.endswith(f"{n}/w") for n in ("q", "k", "v")) or "/in_" in ps or ps.endswith("in_proj/w"):
            return _wspec(pol, rank, 1, leaf, rank - 2) if div(rank - 1) else P(*([None] * rank))
        if ps.endswith("o/w") or ps.endswith("out/w") or ps.endswith("out_proj/w"):
            return _wspec(pol, rank, 0, leaf, rank - 2) if div(rank - 2) else P(*([None] * rank))
        # ---- mlp
        if ps.endswith("gate/w") or ps.endswith("up/w") or ps.endswith("fc1/w"):
            return _wspec(pol, rank, 1, leaf, rank - 2) if div(rank - 1) else P(*([None] * rank))
        if ps.endswith("down/w") or ps.endswith("fc2/w"):
            return _wspec(pol, rank, 0, leaf, rank - 2) if div(rank - 2) else P(*([None] * rank))
        # ---- hybrid gates (w x w): shard output
        if ps.endswith("gate_r/w") or ps.endswith("gate_i/w"):
            return _wspec(pol, rank, 1, leaf, rank - 2) if div(rank - 1) else P(*([None] * rank))
        # ---- everything else (norms, biases, conv, scalars): replicated
        return P(*([None] * rank))

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def cache_specs(cfg, cache_shape, pol: ShardingPolicy, batch: int,
                shard_seq: bool = True):
    """KV/state caches: batch on data when divisible.

    KV ring buffers [L, B, W, Kv, D] additionally shard the sequence axis W on
    the model axis when divisible (sequence-parallel cache): attention over the
    cache becomes a sharded contraction that GSPMD resolves with partial
    softmax terms + a small all-reduce, while the cache itself — the dominant
    serving tensor — shrinks by the model-axis size per device.
    """
    b_ax = pol.batch_axis(batch)
    m_size = pol.axis_size(pol.model)

    def rule(path, leaf):
        rank = len(leaf.shape)
        if rank == 0:
            return P()
        spec = [None] * rank
        # batch dim position is structural: hybrid "tail" caches are unstacked
        # ([B, ...]); every other cache carries a leading layer/block stack
        # ([L, B, ...]). Never guess by size — L can collide with B.
        bdim = 0 if "tail" in _path_str(path) else 1
        if bdim < rank and leaf.shape[bdim] == batch:
            spec[bdim] = b_ax
        key = _path_str(path).split("/")[-1]
        if shard_seq and key in ("k", "v") and rank == 5 and m_size > 1:
            if b_ax is None:
                # batch replicated (2D-TP serving): spread W over EVERY axis
                d_names = (() if pol.data is None else
                           ((pol.data,) if isinstance(pol.data, str) else tuple(pol.data)))
                m_names = ((pol.model,) if isinstance(pol.model, str)
                           else tuple(pol.model))
                full = d_names + m_names
                sz = pol.axis_size(pol.data) * m_size
                if leaf.shape[2] % sz == 0:
                    spec[2] = full
                elif leaf.shape[2] % m_size == 0:
                    spec[2] = pol.model
            elif leaf.shape[2] % m_size == 0:
                spec[2] = pol.model
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def io_specs(pol: ShardingPolicy, batch: int):
    """(tokens_spec, logits_spec) for step functions."""
    b_ax = pol.batch_axis(batch)
    return P(b_ax, None), P(b_ax, None, pol.model)


# ---------------------------------------------------------------------------
# spec-tree -> sharding-tree assembly (shared by the dry-run step builders in
# launch/steps.py and the placement lowering layer in api/placement.py)
# ---------------------------------------------------------------------------
def ns_tree(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree on ``mesh``."""
    from jax.sharding import NamedSharding
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def sds_with(shard_tree, shape_tree):
    """Attach a sharding tree to a ShapeDtypeStruct tree (dry-run inputs)."""
    return jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh),
        shape_tree, shard_tree)
