"""The single place ``src/repro`` reads real clocks (CI greps for this).

Everything else in the package takes a clock as a parameter (``Tracer``,
``ServingMetrics``, ``RoundEventLog``) or imports these two callables, so
tests can substitute ``ManualClock`` and drive time deterministically.

  * ``perf()`` — monotonic, high-resolution; use for durations (spans).
  * ``wall()`` — epoch seconds; use for timestamps (request arrival).
"""
from __future__ import annotations

import time

perf = time.perf_counter
wall = time.time


class ManualClock:
    """A callable clock for tests: returns a fixed value until advanced."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t
