"""Online predicted-vs-measured cost-model drift detection.

The paper's claim is that Eq. (1) and the placement round-time model
*predict* serving behaviour. Offline, ``bench_dse`` checks that once; this
monitor runs the same validation loop continuously against live rounds.

Units. The cost model is dimensionless — it prices a round in units of one
target forward pass (t_target): draft costs ``gamma * c``, verify costs
``1``, a round costs ``round_time(gamma, c, h) = gamma*c + 1 + h``. To
compare against measured seconds the monitor needs the t_target unit in
seconds, which it **calibrates from the first ``calibration_rounds``
observed rounds and thereafter only ratchets DOWN** (to the fastest verify
ever seen — compile rounds and contention are strictly slower, so min is
the clean sample). Never up: if the unit tracked the measurement, a
uniformly slowing system would hide perfectly inside a self-updating unit.
Component predictions with no model term (commit,
handoff — both folded into the dispatch overhead ``h`` analytically) are
calibrated the same way, so for them the monitor detects *change from the
calibrated baseline* rather than absolute model error.

Per component it keeps an EMA of measured seconds and of predicted seconds
(predictions vary round to round with gamma), and flags when the relative
error leaves the tolerance band for ``min_samples``+ observations:
"cost model is wrong by X% on component Y".

``evidence()`` turns sustained drift back into planner inputs (measured
t_draft / t_target / dispatch_overhead) — see
``api/feedback.respec_from_drift``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core import cost_model

COMPONENTS = ("draft", "verify", "commit", "handoff", "prefill", "round")


@dataclass(frozen=True)
class DriftConfig:
    ema: float = 0.9               # smoothing for measured/predicted EMAs
    tol: float = 0.25              # flag when |measured/predicted - 1| > tol
    warmup_rounds: int = 1         # observations dropped before calibrating
                                   # (the first round pays XLA compilation —
                                   # letting it into the unit would dwarf
                                   # every steady-state measurement)
    calibration_rounds: int = 3    # rounds used to pin the t_target unit
    min_samples: int = 3           # post-calibration obs before flagging
    min_abs: float = 0.0           # absolute floor (s) on flagged deltas


class _Component:
    __slots__ = ("measured", "units", "n")

    def __init__(self):
        self.measured: Optional[float] = None   # seconds, EMA
        self.units: Optional[float] = None      # t_target units, EMA
        self.n = 0

    def observe(self, measured_s: float, units: float, ema: float):
        if self.measured is None:
            self.measured, self.units = measured_s, units
        else:
            self.measured = ema * self.measured + (1 - ema) * measured_s
            self.units = ema * self.units + (1 - ema) * units
        self.n += 1


class DriftMonitor:
    """Compare measured round/phase times against the planner's cost model.

    ``c``/``dispatch_overhead``/``overlap`` are the values the plan was made
    with; ``gamma`` is the default draft length (overridable per observation
    since the scheduler retunes gamma online).
    """

    def __init__(self, gamma: int, c: float,
                 dispatch_overhead: float = cost_model.DISPATCH_OVERHEAD_DEFAULT,
                 overlap: bool = False, cfg: Optional[DriftConfig] = None):
        self.gamma = max(int(gamma), 1)
        self.c = float(c)
        self.h = float(dispatch_overhead)
        self.overlap = bool(overlap)
        self.cfg = cfg or DriftConfig()
        self.unit: Optional[float] = None          # t_target in seconds
        self._warmup_left = self.cfg.warmup_rounds
        self._cal: Dict[str, List[float]] = {k: [] for k in COMPONENTS}
        self._cal_rounds = 0
        self._baseline_units: Dict[str, float] = {}  # commit/handoff
        self._comp: Dict[str, _Component] = {k: _Component()
                                             for k in COMPONENTS}
        self._draft_per_token: Optional[float] = None  # seconds, EMA

    # ----------------------------------------------------------- predictions
    def predicted_units(self, component: str,
                        gamma: Optional[int] = None) -> Optional[float]:
        """Model-predicted cost of ``component`` in t_target units."""
        g = self.gamma if gamma is None else max(int(gamma), 1)
        if component == "draft":
            return g * self.c
        if component == "verify":
            return 1.0
        if component == "round":
            return cost_model.round_time(g, self.c, self.h, self.overlap)
        return self._baseline_units.get(component)   # commit/handoff/prefill

    # ------------------------------------------------------------ observation
    def observe(self, t_round: Optional[float] = None,
                t_draft: Optional[float] = None,
                t_verify: Optional[float] = None,
                t_commit: Optional[float] = None,
                t_handoff: Optional[float] = None,
                t_prefill: Optional[float] = None,
                gamma: Optional[int] = None):
        """Feed one round's measured seconds (any subset of components).
        ``t_prefill`` is the interleaved chunk-program time of steps that
        advanced a prefill (one fixed-size chunk per step, so the baseline
        is as uniform as the other no-model-term components)."""
        if self._warmup_left > 0:
            self._warmup_left -= 1
            return
        g = self.gamma if gamma is None else max(int(gamma), 1)
        measured = {"draft": t_draft, "verify": t_verify, "commit": t_commit,
                    "handoff": t_handoff, "prefill": t_prefill,
                    "round": t_round}
        if self.unit is None:
            self._calibrate(measured, g)
            return
        # The unit only ratchets DOWN: the fastest verify ever seen is the
        # cleanest t_target sample (compile rounds, new-shape recompiles and
        # host contention are all strictly slower). report() scales the
        # units-EMA by the current unit, so a late refinement applies
        # retroactively; a unit that could rise would hide real slowdowns.
        if t_verify is not None:
            self.unit = min(self.unit, float(t_verify))
        ema = self.cfg.ema
        for comp, t in measured.items():
            if t is None:
                continue
            units = self.predicted_units(comp, g)
            if units is None:
                # component with no model term and no calibration sample:
                # its first live observation becomes the baseline
                self._baseline_units[comp] = t / self.unit
                units = self._baseline_units[comp]
            self._comp[comp].observe(float(t), units, ema)
        if t_draft is not None:
            per_tok = float(t_draft) / g
            self._draft_per_token = (per_tok if self._draft_per_token is None
                                     else ema * self._draft_per_token
                                     + (1 - ema) * per_tok)

    def _calibrate(self, measured: Dict[str, Optional[float]], g: int):
        for comp, t in measured.items():
            if t is not None:
                self._cal[comp].append(float(t))
        self._cal_rounds += 1
        if self._cal_rounds < self.cfg.calibration_rounds:
            return
        # Pin the t_target unit: prefer measured verify (verify IS one
        # target pass); fall back to the full round over its model cost.
        # min, not mean — first calls pay XLA compilation, and every new
        # (gamma, bucket) shape inside the window recompiles; the fastest
        # sample is the clean one.
        if self._cal["verify"]:
            self.unit = min(self._cal["verify"])
        elif self._cal["round"]:
            self.unit = min(self._cal["round"]) / cost_model.round_time(
                g, self.c, self.h, self.overlap)
        else:
            self._cal_rounds -= 1    # nothing usable yet; keep calibrating
            return
        for comp in ("commit", "handoff", "prefill"):
            if self._cal[comp]:
                self._baseline_units[comp] = min(self._cal[comp]) / self.unit

    # ---------------------------------------------------------------- reports
    @property
    def calibrated(self) -> bool:
        return self.unit is not None

    def report(self) -> Dict[str, dict]:
        """Per-component predicted vs measured seconds and drift verdict."""
        out: Dict[str, dict] = {}
        for comp in COMPONENTS:
            c = self._comp[comp]
            if c.n == 0:
                continue
            predicted = (c.units * self.unit
                         if c.units is not None and self.unit else None)
            err = (c.measured / predicted - 1.0
                   if predicted and predicted > 0 else None)
            flagged = (err is not None and c.n >= self.cfg.min_samples
                       and abs(err) > self.cfg.tol
                       and abs(c.measured - predicted) > self.cfg.min_abs)
            out[comp] = {"predicted_s": predicted, "measured_s": c.measured,
                         "rel_err": err, "flagged": flagged, "n": c.n}
        return out

    def alerts(self) -> List[str]:
        msgs = []
        for comp, r in self.report().items():
            if r["flagged"]:
                msgs.append(
                    f"cost model is wrong by {r['rel_err']:+.0%} on component "
                    f"'{comp}' (predicted {r['predicted_s'] * 1e3:.2f} ms, "
                    f"measured {r['measured_s'] * 1e3:.2f} ms)")
        return msgs

    def evidence(self) -> Optional[dict]:
        """Measured planner inputs, for re-planning. None until the monitor
        has both a unit and a draft observation."""
        if self.unit is None:
            return None
        verify = self._comp["verify"]
        t_target = verify.measured if verify.n else self.unit
        if self._draft_per_token is None:
            return None
        ev = {"t_target": t_target, "t_draft": self._draft_per_token,
              "c": self._draft_per_token / t_target}
        rnd, draft = self._comp["round"], self._comp["draft"]
        if rnd.n and draft.n and verify.n:
            extra = rnd.measured - draft.measured - verify.measured
            for comp in ("commit", "handoff"):
                if self._comp[comp].n:
                    extra -= self._comp[comp].measured
            ev["dispatch_overhead"] = max(extra / t_target, 0.0)
        return ev

    def to_dict(self) -> dict:
        return {"gamma": self.gamma, "c": self.c, "h": self.h,
                "overlap": self.overlap, "unit_s": self.unit,
                "report": self.report(), "alerts": self.alerts(),
                "evidence": self.evidence()}
