"""Span-based tracing with Chrome-trace (Perfetto-loadable) export.

Design constraints, in order:

  1. **Free when off.** ``Tracer(enabled=False).span(...)`` returns a shared
     module-level null context manager — no allocation, no clock read, no
     lock. Hot loops can keep unconditional ``with tracer.span(...):`` lines.
  2. **Honest when on.** A span measures host wall time between ``__enter__``
     and ``__exit__``. JAX dispatch is async, so callers that want a span to
     mean "device phase time" must call ``jax.block_until_ready`` *inside*
     the span (see ``core/rounds.TracedRound``); callers that want "host
     dispatch time" simply don't block (see ``PlacedRound``). The tracer
     itself never touches device state.
  3. **Bounded.** Spans land in a ring buffer (``capacity``); a long-running
     server keeps the most recent window instead of growing without bound.

Spans carry free-form tags. Two are special on export: ``role`` selects the
timeline row (host / drafter-mesh / target-mesh), ``phase`` becomes the
event category (draft / verify / commit / ...).
"""
from __future__ import annotations

import json
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.obs import clock as _clock

_US = 1e6  # chrome trace wants microseconds


@dataclass(frozen=True)
class Span:
    """One closed span. ``t0``/``t1`` are in the tracer's clock domain."""
    name: str
    t0: float
    t1: float
    depth: int
    thread: int
    tags: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


class _NullSpan:
    """Shared no-op context manager returned by a disabled tracer."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    @property
    def duration(self) -> float:
        return 0.0


_NULL_SPAN = _NullSpan()


class _LiveSpan:
    __slots__ = ("_tracer", "name", "tags", "t0", "t1", "depth")

    def __init__(self, tracer: "Tracer", name: str, tags: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.tags = tags
        self.t0 = 0.0
        self.t1 = 0.0
        self.depth = 0

    def __enter__(self) -> "_LiveSpan":
        tr = self._tracer
        self.depth = tr._enter_depth()
        self.t0 = tr.clock()
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tracer
        self.t1 = tr.clock()
        tr._exit_depth()
        tr._record(self)
        return False

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


class Tracer:
    """Thread-safe ring-buffered span collector with an injectable clock."""

    def __init__(self, enabled: bool = True, capacity: int = 65536,
                 clock: Optional[Callable[[], float]] = None):
        self.enabled = bool(enabled)
        self.clock = clock if clock is not None else _clock.perf
        self._spans: deque = deque(maxlen=int(capacity))
        self._lock = threading.Lock()
        self._depths = threading.local()

    # ------------------------------------------------------------- recording
    def span(self, name: str, **tags):
        """Open a span. Use as ``with tracer.span("draft", phase="draft"):``.
        Returns a shared null object when disabled (no allocation)."""
        if not self.enabled:
            return _NULL_SPAN
        return _LiveSpan(self, name, tags)

    def _enter_depth(self) -> int:
        d = getattr(self._depths, "v", 0)
        self._depths.v = d + 1
        return d

    def _exit_depth(self):
        self._depths.v = getattr(self._depths, "v", 1) - 1

    def _record(self, live: _LiveSpan):
        span = Span(live.name, live.t0, live.t1, live.depth,
                    threading.get_ident(), live.tags)
        with self._lock:
            self._spans.append(span)

    # --------------------------------------------------------------- queries
    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._spans)

    def clear(self):
        with self._lock:
            self._spans.clear()

    def _matches(self, s: Span, match: Dict[str, Any]) -> bool:
        for k, v in match.items():
            if k == "name":
                if s.name != v:
                    return False
            elif s.tags.get(k) != v:
                return False
        return True

    def total(self, **match) -> float:
        """Summed duration of spans whose name/tags equal all of ``match``."""
        return sum(s.duration for s in self.spans() if self._matches(s, match))

    def count(self, **match) -> int:
        return sum(1 for s in self.spans() if self._matches(s, match))

    def phase_totals(self) -> Dict[str, float]:
        """Summed duration per ``phase`` tag — the per-phase breakdown."""
        out: Dict[str, float] = {}
        for s in self.spans():
            phase = s.tags.get("phase")
            if phase is not None:
                out[phase] = out.get(phase, 0.0) + s.duration
        return out

    # ---------------------------------------------------------------- export
    def to_chrome_trace(self) -> dict:
        """Chrome-trace JSON object (load in chrome://tracing or Perfetto).

        Rows (tids) are the span ``role`` tags — host orchestration vs the
        drafter/target meshes — named via "M" metadata events; each span is
        one complete "X" event with its tags as args.
        """
        rows: Dict[str, int] = {}
        events = []
        for s in self.spans():
            role = str(s.tags.get("role") or "host")
            tid = rows.setdefault(role, len(rows))
            events.append({
                "name": s.name,
                "ph": "X",
                "pid": 0,
                "tid": tid,
                "ts": s.t0 * _US,
                "dur": max(s.duration, 0.0) * _US,
                "cat": str(s.tags.get("phase") or s.name),
                "args": {k: v for k, v in s.tags.items() if v is not None},
            })
        meta = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                 "args": {"name": role}} for role, tid in rows.items()]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, default=str)
        return path


#: Shared disabled tracer — the default everywhere a tracer is optional, so
#: call sites never branch on ``tracer is not None``.
NULL_TRACER = Tracer(enabled=False, capacity=1)
