"""Typed per-round event log for speculative serving.

``RoundEvent`` is the unit of record: one speculative (or AR) round, with
what the scheduler decided (gamma), what the sampler did (per-row accepted
draft tokens), what it cost (host wall time, per-phase times when the run
is traced, placement handoff time) and what it moved (KV blocks read /
written). This subsumes the round-level counters in
``serving/metrics.py`` — ``RoundEventLog.alpha_hat()`` reproduces
``ServingMetrics.alpha_hat()`` exactly (same per-row EMA, parity-tested in
tests/test_obs.py) — and adds the per-round structure the drift monitor
and SLO analysis need.

Events stream to JSONL (``stream_to`` for online appends, ``to_jsonl`` for
a post-hoc dump), one JSON object per line, so a long run can be analysed
without holding it in memory.
"""
from __future__ import annotations

import json
from collections import deque
from dataclasses import asdict, dataclass, field
from typing import IO, Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class RoundEvent:
    round: int                   # global round index within the run
    gamma: int                   # draft length this round (0 == AR round)
    n_active: int                # live rows this round
    accepted: Tuple[int, ...]    # per live row: accepted draft tokens
    emitted: int                 # committed tokens incl. bonus, summed
    t_round: float               # host wall seconds, dispatch -> sync
    t_draft: Optional[float] = None    # phase times: only on traced runs
    t_verify: Optional[float] = None
    t_commit: Optional[float] = None
    t_handoff: Optional[float] = None  # cross-submesh transfer (placed)
    blocks_read: int = 0         # KV blocks touched by reads this round
    blocks_written: int = 0      # KV blocks touched by writes (estimate)
    rids: Tuple[int, ...] = ()   # request ids of the live rows
    t_wall: float = 0.0          # wall-clock timestamp (epoch s)
    queue_depth: int = 0         # requests waiting in the scheduler queue
                                 # while this round ran (SLO analysis)
    n_preempted: int = 0         # rows evicted + re-queued this round
    n_expired: int = 0           # queued requests expired at admission
    n_failed: int = 0            # requests failed terminally this round
    degraded: bool = False       # batch running AR due to watchdog trip /
                                 # drafter failure (not a cost-model choice)
    fault_delay: float = 0.0     # injected virtual straggle included in
                                 # t_round (chaos runs; 0 in production)
    prefill_tokens: int = 0      # suffix tokens prefilled this step (chunked
                                 # prefill interleaves them with the round)
    prefill_chunks: int = 0      # chunk programs run this step
    t_prefill: Optional[float] = None  # host seconds spent in chunk programs
    prefix_hit_rate: Optional[float] = None  # running prefix-cache hit rate
                                 # (tokens attached / candidate tokens)

    @property
    def alpha_round(self) -> Optional[float]:
        """Mean per-row acceptance rate for this round; None for AR rounds."""
        if self.gamma <= 0 or not self.accepted:
            return None
        return float(np.mean([a / self.gamma for a in self.accepted]))

    def to_json(self) -> str:
        return json.dumps(asdict(self), default=float)


class RoundEventLog:
    """Ring-buffered RoundEvent collector with optional JSONL streaming."""

    def __init__(self, capacity: int = 65536, alpha_ema: float = 0.9,
                 stream: Optional[IO[str]] = None):
        self.alpha_ema = alpha_ema
        self._events: deque = deque(maxlen=int(capacity))
        self._alpha: Optional[float] = None
        self._stream = stream
        self.n_rounds = 0
        self.n_spec_rounds = 0
        self.total_emitted = 0

    # ------------------------------------------------------------- recording
    def record(self, ev: RoundEvent):
        self._events.append(ev)
        self.n_rounds += 1
        self.total_emitted += ev.emitted
        if ev.gamma > 0:
            self.n_spec_rounds += 1
            # Same per-row EMA as ServingMetrics.alpha_hat(): each live row
            # contributes one observation acc/gamma, unclamped.
            for acc in ev.accepted:
                alpha_round = max(float(acc), 0.0) / ev.gamma
                self._alpha = (alpha_round if self._alpha is None else
                               self.alpha_ema * self._alpha
                               + (1 - self.alpha_ema) * alpha_round)
        if self._stream is not None:
            self._stream.write(ev.to_json() + "\n")

    # --------------------------------------------------------------- queries
    def events(self) -> List[RoundEvent]:
        return list(self._events)

    def alpha_hat(self) -> Optional[float]:
        """EMA acceptance estimate; parity with ServingMetrics.alpha_hat()."""
        return self._alpha

    def accept_hist(self, gamma_max: int) -> np.ndarray:
        hist = np.zeros(gamma_max + 1, np.int64)
        for ev in self._events:
            if ev.gamma <= 0:
                continue
            for acc in ev.accepted:
                hist[int(min(max(acc, 0), gamma_max))] += 1
        return hist

    def phase_means(self) -> Dict[str, float]:
        """Mean per-phase seconds over events that carry phase times."""
        sums: Dict[str, float] = {}
        counts: Dict[str, int] = {}
        for ev in self._events:
            for key in ("t_round", "t_draft", "t_verify", "t_commit",
                        "t_handoff", "t_prefill"):
                v = getattr(ev, key)
                if v is not None:
                    sums[key] = sums.get(key, 0.0) + v
                    counts[key] = counts.get(key, 0) + 1
        return {k: sums[k] / counts[k] for k in sums}

    # -------------------------------------------------------------- streaming
    def stream_to(self, f: IO[str]):
        """Append every future event to ``f`` as one JSON line each."""
        self._stream = f

    def to_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            for ev in self._events:
                f.write(ev.to_json() + "\n")
        return path
