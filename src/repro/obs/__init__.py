"""Structured telemetry for the speculative-decoding stack.

Three cooperating pieces, all host-side and dependency-free:

  * ``trace``  — span-based tracing with Chrome-trace/Perfetto export, so a
    served workload renders as a draft/verify/commit timeline across the
    drafter-mesh/target-mesh rows.
  * ``events`` — a typed per-round event log (RoundEvent) that subsumes the
    round-level counters in ``serving/metrics.py`` and streams to JSONL.
  * ``drift``  — an online predicted-vs-measured monitor that runs the
    paper's cost-model validation loop continuously: each measured round is
    compared against the ``cost_model.round_time`` terms the planner used,
    and sustained disagreement is surfaced per component.

``clock`` is the ONE module in ``src/repro`` allowed to read wall/perf
clocks (CI-enforced); everything else takes an injectable clock so tests
can drive time manually.
"""
from repro.obs.drift import DriftConfig, DriftMonitor
from repro.obs.events import RoundEvent, RoundEventLog
from repro.obs.trace import NULL_TRACER, Span, Tracer

__all__ = [
    "DriftConfig",
    "DriftMonitor",
    "NULL_TRACER",
    "RoundEvent",
    "RoundEventLog",
    "Span",
    "Tracer",
]
